"""Multi-host collection fleet for the continuous tuning loop.

The single-host loop (``repro.service.loop``) grows the dataset one campaign
pass per cycle — fine for CI, too slow for the paper's "days -> minutes"
claim at fleet scale.  This module fans the *collect* step of each cycle out
over N **collector** processes while keeping the cycle tail (merge -> refit
-> re-recommend) on one **coordinator**, exactly once per cycle:

- The coordinator partitions the campaign with the positional ``--shard h/H``
  slicing collection has used since PR 1 (disjoint and complete), and
  *leases* shard ``i`` to collector ``i``.
- Each collector is a separate process (``--role collector --shard i/N``)
  appending to its own ``shards/host_<i>/cycle_<c>.jsonl`` — no two writers
  ever touch one file — and heartbeating into the shared ``fleet_state.jsonl``.
- The coordinator watches worker exit codes and heartbeat ages; a crashed
  (``kill -9``) or stalled collector gets its shard **re-leased**: campaign
  resume keys ``(case_id, rep, seed)`` mean the replacement re-runs only the
  cases the dead worker never finished.
- After every shard completes, the coordinator merges all shard files into
  the canonical ``merged.jsonl`` and runs refit + re-recommend — the
  ``ContinuousTuningLoop`` cycle tail, unchanged.

**The invariant this layer preserves:** the merged dataset after cycle ``c``
is *byte-identical* no matter how many collectors ran it (and identical to a
single-host ``repro.service.loop`` run), because the canonical merge orders
records by ``(seed window, campaign case position, rep)`` and strips
collection-topology provenance (``campaign.canonical_records``).  Tests
assert this for 1/2/4 collectors and across ``kill -9`` + re-lease
(``tests/test_fleet.py``); ``docs/fleet.md`` documents it.

This module stays import-light on purpose: the collector role needs only the
campaign runner (numpy), not the jax model stack, and collectors are spawned
once per cycle per shard — their interpreter startup is fleet overhead.  The
coordinator half (which does need the full loop) lives in ``_coordinator.py``
and loads lazily.

CLI::

    python -m repro.service.fleet --collectors 4 --fast      # coordinator
    python -m repro.service.fleet --status                   # audit log
    python -m repro.service.fleet --collectors 4 --executor synthetic  # dry run

    # internal, spawned by the coordinator (one per leased shard):
    python -m repro.service.fleet --role collector --cycle 0 --shard 1/4 ...
"""

from __future__ import annotations

import argparse
import dataclasses
import pathlib
import socket
import sys
import threading
import time
import zlib
from typing import Callable, List, Optional, Sequence

from ..data.campaign import run_campaign_batch
from ._cli import add_chaos_args, add_fleet_args, add_tuning_args
from .state import FleetLog

__all__ = [
    "DEFAULT_FLEET_DIR",
    "CollectorConfig",
    "FleetConfig",
    "FleetCoordinator",
    "run_collector",
    "collector_shard_path",
    "synthetic_executor",
    "main",
]

DEFAULT_FLEET_DIR = pathlib.Path("/tmp/repro_io/fleet")

_COORDINATOR_NAMES = ("FleetConfig", "FleetCoordinator")


def __getattr__(name: str):
    # the coordinator half needs the model stack; collectors never touch it
    if name in _COORDINATOR_NAMES:
        from . import _coordinator
        return getattr(_coordinator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class CollectorConfig:
    """The slice of ``FleetConfig`` a collector process needs.

    Deliberately free of ``LoopConfig``/model-stack types so constructing it
    (the ``--role collector`` hot path) stays jax-free."""

    campaign: str
    out_dir: pathlib.Path
    collectors: int
    fast: bool = False
    base_seed: int = 1000
    seed_stride: int = 100
    seeds_per_cycle: int = 1
    executor_kind: str = "real"   # "real" I/O or "synthetic" dry-run rows
    sleep_per_case: float = 0.0   # pacing sleep (scaling experiments/tests)
    heartbeat_every_s: float = 5.0  # liveness tick cadence while collecting
    # Collection hardening, mirroring LoopConfig (docs/robustness.md)
    case_deadline_s: Optional[float] = None
    max_retries: int = 2
    backoff_s: float = 0.05
    quarantine_after: Optional[int] = 3


def collector_shard_path(out_dir, shard: int, cycle: int) -> pathlib.Path:
    """Collector ``shard``'s private JSONL for ``cycle`` — one writer per file."""
    return pathlib.Path(out_dir) / "shards" / f"host_{shard}" / f"cycle_{cycle:04d}.jsonl"


def synthetic_executor(case, ctx, seed: int) -> dict:
    """Deterministic dry-run measurement (no storage I/O).

    A fixed performance model of the knob axes plus seed/case-keyed jitter
    (crc32, not ``hash()``, so rows are stable across processes regardless of
    ``PYTHONHASHSEED``).  This is what makes fleet plumbing testable: any
    collector topology must reproduce these rows byte-for-byte."""
    from ..core.features import TARGET_NAME

    w = case.num_workers
    b = case.batch_size or 64
    thr = 80.0 * (1 + 0.9 * w ** 0.7) * (1 + 0.15 * (case.prefetch_depth - 1))
    thr *= (b / 64.0) ** 0.2 * (1 + 0.1 * (case.n_threads - 1))
    jitter = (seed * 2654435761 + zlib.crc32(case.id.encode())) % 97 - 48
    thr *= 1 + 0.02 * jitter / 48.0
    return {
        TARGET_NAME: thr, "batch_size": b, "num_workers": w,
        "block_kb": case.block_kb, "file_size_mb": case.file_size_mb or 8.0,
        "n_samples": case.n_samples, "n_threads": case.n_threads,
        "bench_type": case.bench_type, "backend": case.backend,
    }


def _configured_executor(cfg, executor: Optional[Callable]) -> Optional[Callable]:
    """Resolve the case executor from config: injected > synthetic > real,
    with the optional per-case pacing sleep wrapped around it."""
    base = executor
    if base is None and cfg.executor_kind == "synthetic":
        base = synthetic_executor
    if cfg.sleep_per_case > 0:
        from ..data.campaign import run_case
        inner = base or run_case

        def paced(case, ctx, seed):
            time.sleep(cfg.sleep_per_case)
            return inner(case, ctx, seed)
        return paced
    return base


def run_collector(
    cfg,
    cycle: int,
    shard: int,
    seeds: Optional[Sequence[int]] = None,
    executor: Optional[Callable] = None,
    progress: Optional[Callable[[str], None]] = None,
    max_cases: Optional[int] = None,
    attempt: int = 0,
) -> List:
    """Collect one leased shard of one cycle (the ``--role collector`` entry).

    ``cfg`` is a :class:`CollectorConfig` or :class:`FleetConfig` (duck-typed
    on the collection fields).  Appends campaign records to this shard's
    private file and heartbeat records (``start`` / per-case / ``shard_done``)
    to the shared fleet log; every record carries the lease ``attempt`` so the
    coordinator can tell this attempt's progress and completion from an
    earlier crashed one's.  The ``shard_done`` record — not the process exit
    code — is what marks the shard complete: case failures are recorded data
    (resume keys re-run them later), not worker crashes.  Re-running after a
    crash resumes case-by-case via campaign resume keys.  ``max_cases`` stops
    after that many executions *without* a ``shard_done`` record — the tests'
    deterministic stand-in for a mid-shard ``kill -9``."""
    log = FleetLog(pathlib.Path(cfg.out_dir) / "fleet_state.jsonl")
    out = collector_shard_path(cfg.out_dir, shard, cycle)
    if seeds is None:
        start = cfg.base_seed + cycle * cfg.seed_stride
        seeds = list(range(start, start + cfg.seeds_per_cycle))
    host = socket.gethostname()
    exec_fn = _configured_executor(cfg, executor)
    n_done = 0

    def on_record(record: dict) -> None:
        nonlocal n_done
        n_done += 1
        log.append({"type": "heartbeat", "event": "case", "cycle": cycle,
                    "shard": shard, "attempt": attempt, "n_done": n_done,
                    "host": host})

    log.append({"type": "heartbeat", "event": "start", "cycle": cycle,
                "shard": shard, "attempt": attempt, "n_done": 0, "host": host})
    # Liveness ticks on a timer thread, independent of case completion: a
    # single slow case (minutes of network/object I/O) must not read as a
    # stale worker.  What staleness then detects is a dead or frozen
    # *process* (kill -9, OOM, SIGSTOP, dead machine) — exit codes catch
    # clean crashes faster, this catches the rest.
    every = getattr(cfg, "heartbeat_every_s", 5.0)
    stop_ticks = threading.Event()

    def _tick():
        while not stop_ticks.wait(every):
            log.append({"type": "heartbeat", "event": "tick", "cycle": cycle,
                        "shard": shard, "attempt": attempt, "n_done": n_done,
                        "host": host})

    ticker = threading.Thread(target=_tick, daemon=True)
    ticker.start()
    try:
        results = run_campaign_batch(
            cfg.campaign, out, seeds, fast=cfg.fast,
            shard=(shard, cfg.collectors), max_cases=max_cases,
            executor=exec_fn, progress=progress, on_record=on_record,
            deadline_s=getattr(cfg, "case_deadline_s", None),
            max_retries=getattr(cfg, "max_retries", 2),
            backoff_s=getattr(cfg, "backoff_s", 0.05),
            quarantine_after=getattr(cfg, "quarantine_after", 3),
        )
    finally:
        stop_ticks.set()
        ticker.join(timeout=2)
    if max_cases is None:  # a simulated kill dies before reporting completion
        log.append({
            "type": "shard_done", "cycle": cycle, "shard": shard,
            "attempt": attempt,
            "n_executed": sum(r.n_executed for r in results),
            "n_failures": sum(len(r.failures) for r in results),
            "n_skipped": sum(r.skipped for r in results),
            "retried": sum(r.retried for r in results),
            "timeouts": sum(r.n_timeouts for r in results),
            "quarantined": sum(r.n_quarantined for r in results),
            "write_retries": sum(r.write_retries for r in results),
            "host": host,
        })
    return results


# ---------------------------------------------------------------- CLI

def build_parser() -> argparse.ArgumentParser:
    """One parser for both roles — every flag is defined exactly once in
    ``_cli.py``, so the coordinator's spawn argv cannot drift from what a
    worker accepts, and parsing stays import-light for the collector role."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.service.fleet",
        description="Multi-host collection fleet: a coordinator leases "
                    "campaign shards to collector processes, re-leases on "
                    "crash/stall, and runs the merge -> refit -> re-recommend "
                    "cycle tail exactly once per cycle.",
    )
    add_tuning_args(ap)
    add_fleet_args(ap, default_out_dir=DEFAULT_FLEET_DIR)
    add_chaos_args(ap)
    return ap


def _collector_main(args: argparse.Namespace,
                    ap: argparse.ArgumentParser) -> int:
    if args.cycle is None or args.shard is None:
        ap.error("--role collector requires --cycle and --shard i/N")
    shard, n = args.shard
    # A coordinator running under chaos exports its plan into the
    # environment; collectors inherit it here so the whole fleet injects
    # faults from one seeded schedule (explicit --chaos-seed wins).
    from ._cli import chaos_plan_from_args
    if chaos_plan_from_args(args) is None:
        from . import faults
        faults.activate_from_env()
    cfg = CollectorConfig(
        campaign=args.campaign, out_dir=args.out_dir, collectors=n,
        fast=args.fast, base_seed=args.base_seed,
        seeds_per_cycle=args.seeds_per_cycle,
        executor_kind=args.executor, sleep_per_case=args.sleep_per_case,
        heartbeat_every_s=args.heartbeat_every,
        case_deadline_s=args.case_deadline, max_retries=args.max_retries,
        quarantine_after=(None if args.quarantine_after <= 0
                          else args.quarantine_after),
    )
    results = run_collector(cfg, args.cycle, shard, seeds=args.seeds,
                            attempt=args.attempt,
                            progress=lambda m: print(m, flush=True))
    # non-zero only informs a human caller: the coordinator keys completion
    # on the shard_done record, so recorded case failures never read as a
    # worker crash (they re-run via resume/repair, like the single-host loop)
    return 1 if any(r.failures for r in results) else 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.role == "collector":
        return _collector_main(args, ap)
    # only the coordinator needs the loop/model stack — imported on demand
    # so collector startup (per cycle per shard) stays jax-free
    from ._coordinator import coordinator_main
    return coordinator_main(args)


if __name__ == "__main__":
    sys.exit(main())
