"""Shared argparse definitions for the service CLIs (loop + fleet).

Import-light on purpose: the fleet's collector role parses the full fleet
parser at startup, and that path must not touch the jax model stack (see
``fleet.py``).  Keeping every flag defined exactly once here is also what
prevents the coordinator's spawn argv from drifting away from what a worker
accepts — a worker rejecting its own spawn arguments would read as a crash
and burn through lease attempts."""

from __future__ import annotations

import argparse
import pathlib

__all__ = ["add_tuning_args", "add_fleet_args", "add_serve_args",
           "add_chaos_args", "chaos_plan_from_args", "parse_shard"]


def add_tuning_args(ap: argparse.ArgumentParser) -> None:
    """Install the flags shared by the single-host loop and the fleet
    coordinator CLIs (``python -m repro.service.loop`` / ``.fleet``)."""
    ap.add_argument("--campaign", default="paper_core",
                    help="registered campaign name (see repro.data.campaign list)")
    ap.add_argument("--cycles", type=int, default=3,
                    help="total cycles the state file targets")
    ap.add_argument("--max-cycles", type=int, default=None,
                    help="run at most N cycles this invocation (kill/resume testing)")
    ap.add_argument("--seeds-per-cycle", type=int, default=1,
                    help="campaign passes per cycle (rows added = cases x this)")
    ap.add_argument("--base-seed", type=int, default=1000,
                    help="first seed of cycle 0's window")
    ap.add_argument("--fast", action="store_true", help="CI-sized campaign subsets")
    ap.add_argument("--model", default="xgboost",
                    help="predictor model key (default: xgboost)")
    ap.add_argument("--top-k", type=int, default=5,
                    help="configs kept in each cycle's ranked() report")
    ap.add_argument("--refit-every", type=int, default=20,
                    help="observations between scheduled refits")
    ap.add_argument("--min-observations", type=int, default=24,
                    help="observations required before the first fit")
    ap.add_argument("--gain-threshold", type=float, default=0.10,
                    help="predicted gain needed to adopt a proposal")
    ap.add_argument("--drift-threshold", type=float, default=0.5,
                    help="median relative error on new rows that forces a refit")
    ap.add_argument("--calibration-k", type=int, default=25,
                    help="max rows for the few-shot residual calibration a "
                         "never-before-seen backend profile triggers instead "
                         "of a full refit (0 = disable calibration)")
    ap.add_argument("--case-deadline", type=float, default=None,
                    help="per-case wall-clock deadline, seconds (a case "
                         "overrunning it is recorded as a timeout failure; "
                         "default: none)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="transient-failure retries per case (exponential "
                         "backoff with deterministic jitter)")
    ap.add_argument("--quarantine-after", type=int, default=3,
                    help="permanent/timeout failures before a case key is "
                         "quarantined and skipped by resume (0 = never)")
    ap.add_argument("--status", action="store_true",
                    help="print the cycle log (with per-host provenance) and exit")
    ap.add_argument("--force", action="store_true",
                    help="discard state + shards and start over")


def add_serve_args(ap: argparse.ArgumentParser,
                   default_out_dir: pathlib.Path) -> None:
    """The serving tier's own flags (``python -m repro.service.serve``).

    Composes with ``add_tuning_args``: the tuning flags configure the model
    source (embedded loop or standalone autotuner), these configure how it is
    served — binding, micro-batching, response cache, warm start."""
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address (default: loopback)")
    ap.add_argument("--port", type=int, default=0,
                    help="bind port (0 = OS-assigned; see serve_info.json)")
    ap.add_argument("--out-dir", type=pathlib.Path, default=default_out_dir,
                    help="serve_info.json home; with --loop also the loop's "
                         "state + shard directory (resume key)")
    ap.add_argument("--loop", action="store_true",
                    help="run the continuous tuning loop in a background "
                         "thread, hot-swapping the served model on refit")
    ap.add_argument("--warm-from", type=pathlib.Path, default=None,
                    help="campaign/merged JSONL to ingest + fit before "
                         "serving (a frozen warm-started model)")
    ap.add_argument("--no-batch", action="store_true",
                    help="score each request inline (unbatched baseline)")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="micro-batch size cap")
    ap.add_argument("--batch-window-ms", type=float, default=0.0,
                    help="hold a forming batch open this long for stragglers "
                         "(0 = drain-only, no added latency)")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission bound: requests past this queue depth "
                         "are shed with 503 + Retry-After (0 = unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=60000.0,
                    help="per-request queue+scoring budget; a request that "
                         "overruns it gets 504 (0 = no deadline)")
    ap.add_argument("--cache-size", type=int, default=1024,
                    help="response cache capacity (LRU entries)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the response cache")
    ap.add_argument("--smoke", action="store_true",
                    help="self-contained end-to-end check: warm-fit a "
                         "synthetic sweep, serve, hit every endpoint over "
                         "HTTP, verify, drain, exit")


def add_chaos_args(ap: argparse.ArgumentParser) -> None:
    """Deterministic fault-injection flags (``repro.service.faults``).

    Off by default; ``--chaos-seed`` activates the standard plan across the
    whole process — and, via the inherited environment, across every fleet
    collector it spawns (``docs/robustness.md``)."""
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="activate the deterministic fault-injection plan "
                         "with this seed (default: chaos off)")
    ap.add_argument("--chaos-every", type=int, default=0,
                    help="fire each fault stream every N checks "
                         "(deterministic schedule; default 5 when --chaos-seed "
                         "is set and no --chaos-rate given)")
    ap.add_argument("--chaos-rate", type=float, default=0.0,
                    help="fire each fault stream with this seeded probability "
                         "per check (alternative to --chaos-every)")


def chaos_plan_from_args(args: argparse.Namespace):
    """Activate (and return) the fault plan requested by ``add_chaos_args``
    flags, or None.  Imports the faults machinery only when chaos is on."""
    if getattr(args, "chaos_seed", None) is None:
        return None
    from . import faults

    return faults.activate(faults.default_plan(
        args.chaos_seed, rate=args.chaos_rate, every=args.chaos_every))


def parse_shard(s: str):
    try:
        h, n = s.split("/")
        return int(h), int(n)
    except ValueError:
        raise argparse.ArgumentTypeError(f"--shard wants 'i/N', got {s!r}") from None


def add_fleet_args(ap: argparse.ArgumentParser,
                   default_out_dir: pathlib.Path) -> None:
    """The fleet CLI's own flags (coordinator supervision + collector role).

    One definition serves both roles, so everything the coordinator forwards
    to a spawned worker is a flag the worker's parser accepts by construction."""
    ap.add_argument("--role", choices=("coordinator", "collector"),
                    default="coordinator",
                    help="coordinator supervises a full fleet run; collector "
                         "is the internal per-shard worker entry")
    ap.add_argument("--out-dir", type=pathlib.Path, default=default_out_dir,
                    help="shared state + shard directory (resume key)")
    ap.add_argument("--collectors", type=int, default=2,
                    help="collector worker processes (= campaign shards)")
    ap.add_argument("--heartbeat-timeout", type=float, default=60.0,
                    help="seconds of heartbeat silence before a live worker "
                         "is declared stale (dead/frozen process) and its "
                         "shard re-leased")
    ap.add_argument("--heartbeat-every", type=float, default=5.0,
                    help="collector liveness-tick cadence, seconds (ticks "
                         "continue during long-running cases)")
    ap.add_argument("--poll-interval", type=float, default=0.2,
                    help="coordinator supervision poll cadence, seconds")
    ap.add_argument("--max-leases", type=int, default=3,
                    help="lease attempts per shard per cycle before giving up")
    ap.add_argument("--executor", choices=("real", "synthetic"), default="real",
                    help="synthetic = deterministic dry-run rows, no storage "
                         "I/O (fleet plumbing tests and demos)")
    ap.add_argument("--sleep-per-case", type=float, default=0.0,
                    help="pacing sleep before each case, seconds (scaling "
                         "experiments and kill/recovery tests)")
    ap.add_argument("--cycle", type=int, default=None,
                    help="collector role: cycle index being collected")
    ap.add_argument("--shard", type=parse_shard, default=None, metavar="i/N",
                    help="collector role: leased shard i of N")
    ap.add_argument("--seeds", type=int, nargs="+", default=None,
                    help="collector role: explicit seed window for the cycle")
    ap.add_argument("--attempt", type=int, default=0,
                    help="collector role: lease attempt index (internal)")
