"""Resumable per-cycle state for the continuous tuning loop + the fleet's
shared lease/heartbeat log.

One JSONL line per *completed* cycle (the same durability model as the
campaign runner: a killed loop loses at most the in-flight cycle, and its
partially collected shard file resumes case-by-case anyway).  Each record
carries the cycle's full provenance — seed window, dataset growth, refit and
recommend latency, drift score, per-host collection stats, and the decision
taken — so the state file doubles as the loop's audit log.

Record schema (``STATE_SCHEMA_VERSION = 4``)::

    {
      "schema_version": 4,
      "cycle": 0,                      # 0-based cycle index (the resume key)
      "status": "ok",
      "campaign": "paper_core",
      "fast": true,
      "seeds": [1000, 1001],           # the cycle's seed window
      "n_executed": 26,                # cases run this cycle (0 after resume)
      "n_failures": 0,
      "collectors": 1,                 # collection processes (1 = single host)
      "releases": 0,                   # shard re-leases after crash/stall
      "hosts": {                       # per-host provenance, keyed by shard
        "host_0": {"host": "box-a", "n_executed": 26, "n_failures": 0,
                   "releases": 0}
      },
      "n_records_merged": 52,          # records in merged.jsonl after merge
      "n_new_rows": 26,                # rows newly ingested by the autotuner
      "n_observations": 52,            # autotuner store size after ingest
      "refit": true,                   # did maybe_refit() fit a model
      "drift": 0.18,                   # median rel. error on new rows (null
                                       #   until a previous model existed)
      "refit_s": 0.41,
      "recommend_s": 0.007,
      "top": [{...top-k configs...}],  # ranked() report, predicted MB/s each
      "decision": {"reconfigure": true, "predicted_gain": 0.31,
                   "explore": false, "config": {...knobs...}},
      "faults": {                      # v3 hardening provenance
        "retried": 0, "timeouts": 0, "quarantined": 0, "write_retries": 0,
        "corrupt_lines": 0, "rejected_rows": 0, "rollback": false
      },
      "transfer": {                    # v4 cross-backend provenance
        "new_profiles": [],            #   backend profiles first seen here
        "known_profiles": 0,           #   distinct profiles known after cycle
        "calibrated": false,           #   few-shot calibration ran instead
                                       #     of a full refit this cycle
        "calibration_rows": 0,         #   rows consumed by the calibrator(s)
        "calibrations": {}             #   backend -> affine (a, b) log-space
      },
      "current_config": {...knobs...}, # config in force AFTER this cycle
      "elapsed_s": 3.2,
      "host": "...", "timestamp": 1780000000.0
    }

Version 1 records (pre-fleet) had no ``collectors``/``releases``/``hosts``;
:func:`upgrade_record` synthesizes them from the flat ``host``/``n_executed``
fields, so old ``loop_state.jsonl`` files keep resuming and rendering under
the current readers — fleet and single-host cycles share one schema.
Version 3 adds the ``faults`` provenance block (retry / timeout / quarantine
/ write-retry / corrupt-line / rejected-row counts plus the refit
``rollback`` flag — see ``docs/robustness.md``); the v2 -> v3 upgrade
synthesizes a zeroed block, so pre-hardening state files read as fault-free.
Version 4 adds the ``transfer`` provenance block (never-before-seen backend
profiles and the few-shot calibrations they triggered — see
``docs/transfer.md``); the v3 -> v4 upgrade synthesizes an all-clear block,
so pre-transfer state files read as "no new profiles ever appeared".

``LoopState`` dedups by cycle keeping the latest record, tolerating the
torn-trailing-line artifacts of a killed writer AND of a writer caught
mid-append by a concurrent reader: :func:`read_complete_records` consumes
only newline-terminated lines, so ``--status`` and the serving tier's
``/stats`` endpoint can poll the state file while the loop appends to it.

``FleetLog`` is the fleet's shared append-only JSONL (``fleet_state.jsonl``):
the coordinator appends one ``lease`` record per shard lease, collectors
append ``heartbeat`` records as they work and one ``shard_done`` at the end.
Every write is one short ``O_APPEND`` line flushed in a single ``write()``
call — on local POSIX filesystems (the shipped subprocess transport)
concurrent appenders don't interleave within a line, and the reader skips
any malformed line defensively.  Sharing the out-dir over NFS-style network
filesystems is NOT safe for concurrent appends (``O_APPEND`` is not atomic
there); a cross-machine transport should give each host its own log file or
route records through the coordinator (see docs/fleet.md).
"""

from __future__ import annotations

import json
import os
import pathlib
import threading
import time
from typing import Dict, List, Optional, Union

__all__ = ["STATE_SCHEMA_VERSION", "ZERO_FAULTS", "ZERO_TRANSFER",
           "LoopState", "FleetLog", "upgrade_record",
           "read_complete_records"]

STATE_SCHEMA_VERSION = 4

# The v3 ``faults`` provenance block, all-clear.  Every cycle record carries
# one; the v2 -> v3 upgrade synthesizes this for pre-hardening records.
ZERO_FAULTS = {
    "retried": 0,         # transient-failure retry attempts (collection)
    "timeouts": 0,        # cases that overran the per-case deadline
    "quarantined": 0,     # keys quarantined after repeated permanent failures
    "write_retries": 0,   # durable-append recoveries (ENOSPC / torn write)
    "corrupt_lines": 0,   # malformed shard lines skipped during merge
    "rejected_rows": 0,   # rows the refit validation guard refused to ingest
    "rollback": False,    # did this cycle roll the model back a generation
}

# The v4 ``transfer`` provenance block, all-clear: no never-before-seen
# backend profile appeared, so no few-shot calibration ran.  The v3 -> v4
# upgrade synthesizes this for pre-transfer records.
ZERO_TRANSFER = {
    "new_profiles": [],    # backend profiles first seen this cycle
    "known_profiles": 0,   # distinct profiles known after this cycle
    "calibrated": False,   # few-shot calibration ran instead of a refit
    "calibration_rows": 0, # rows consumed by the calibrator(s)
    "calibrations": {},    # backend -> affine (a, b) in log1p space
}


def _fault_plan():
    """The active fault-injection plan, if the faults module is even loaded.

    Checked lazily via sys.modules so this hot path never imports (or pays
    for) the chaos machinery outside chaos runs."""
    import sys as _sys

    faults = _sys.modules.get("repro.service.faults")
    return faults.active_plan() if faults is not None else None


def read_complete_records(path: Union[str, pathlib.Path],
                          counters: Optional[dict] = None) -> List[dict]:
    """JSONL records from ``path``, consuming only newline-TERMINATED lines.

    The readers of these logs (``--status``, the serving tier's ``/stats``)
    run concurrently with an appending writer.  A writer caught mid-record
    leaves an unterminated tail; reading it with a text-mode line splitter
    would hand the parser a torn prefix.  Cutting the byte stream at the last
    ``\\n`` consumes exactly the records whose final newline has landed — a
    record is either fully visible or not yet there, never half-read.
    Malformed *complete* lines (foreign corruption) are skipped defensively,
    like the campaign loader and ``FleetLog`` do — pass a ``counters`` dict
    to have their count accumulated in ``counters["corrupt_lines"]``."""
    path = pathlib.Path(path)
    try:
        raw = path.read_bytes()
    except OSError:
        return []
    end = raw.rfind(b"\n")
    if end < 0:
        return []
    records = []
    for line in raw[: end + 1].splitlines():
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            if counters is not None:
                counters["corrupt_lines"] = \
                    counters.get("corrupt_lines", 0) + 1
            continue
    return records


def upgrade_record(record: dict) -> dict:
    """Migrate a cycle record to the current schema (no-op when current).

    v1 -> v2: synthesize the per-host provenance block (``collectors``,
    ``releases``, ``hosts``) from the flat single-host fields, so state files
    written before the fleet subsystem keep working unmodified on disk.

    v2 -> v3: synthesize a zeroed ``faults`` block — a pre-hardening cycle
    recorded no fault provenance, which reads as "none observed".

    v3 -> v4: synthesize an all-clear ``transfer`` block — a pre-transfer
    cycle never detected a new backend profile nor ran a calibration."""
    if record.get("schema_version", 1) >= STATE_SCHEMA_VERSION:
        return record
    record = dict(record)
    record.setdefault("collectors", 1)
    record.setdefault("releases", 0)
    record.setdefault("hosts", {"host_0": {
        "host": record.get("host", ""),
        "n_executed": record.get("n_executed", 0),
        "n_failures": record.get("n_failures", 0),
        "releases": 0,
    }})
    record.setdefault("faults", dict(ZERO_FAULTS))
    record.setdefault("transfer", {**ZERO_TRANSFER, "new_profiles": [],
                                   "calibrations": {}})
    record["schema_version"] = STATE_SCHEMA_VERSION
    return record


class LoopState:
    """Append-only JSONL cycle log with resume semantics."""

    def __init__(self, path: Union[str, pathlib.Path]):
        self.path = pathlib.Path(path)
        self.corrupt_lines = 0  # malformed complete lines seen by last read

    def cycles(self) -> List[dict]:
        """Completed cycle records, deduplicated by cycle (latest wins),
        ordered by cycle index and migrated to the current schema.

        Safe against a concurrently appending writer: only newline-terminated
        records are consumed (``read_complete_records``), so ``--status`` and
        the serving tier's ``/stats`` can poll a live loop's state file.
        Malformed lines are skipped and tallied in ``self.corrupt_lines``."""
        counters: Dict[str, int] = {}
        latest: Dict[int, dict] = {}
        for r in read_complete_records(self.path, counters):
            if r.get("status") == "ok" and "cycle" in r:
                latest[int(r["cycle"])] = upgrade_record(r)
        self.corrupt_lines = counters.get("corrupt_lines", 0)
        return [latest[c] for c in sorted(latest)]

    def next_cycle(self) -> int:
        """First cycle index not yet completed (cycles run in order, so this
        is one past the highest completed index)."""
        done = self.cycles()
        return int(done[-1]["cycle"]) + 1 if done else 0

    def current_config(self) -> Optional[dict]:
        """The config in force after the last completed cycle — restored on
        resume so a killed loop keeps tuning from where it left off."""
        done = self.cycles()
        return dict(done[-1]["current_config"]) if done else None

    def append(self, record: dict) -> None:
        """Durably append one completed-cycle record.

        Under an active chaos plan, a scheduled ``corrupt_line`` fault writes
        a garbage line *before* the real record (loss-free injection: the
        record itself always lands intact — what's being exercised is the
        readers' skip-and-count path)."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._repair_tail()
        plan = _fault_plan()
        garbage = plan.corrupt_line(f"log:{self.path.name}") if plan else None
        with open(self.path, "a") as f:
            if garbage is not None:
                f.write(garbage + "\n")
            f.write(json.dumps(record) + "\n")
            f.flush()

    def _repair_tail(self) -> None:
        """Repair an un-terminated final line before appending — otherwise
        the new record would glue onto it and both would read back as one
        corrupt line.  A malformed tail (torn write) is truncated; a valid
        one that only lost its newline is sealed.  Safe here because the
        state file has exactly one writer (the loop/coordinator process)."""
        try:
            data = self.path.read_bytes()
        except OSError:
            return
        if not data or data.endswith(b"\n"):
            return
        tail = data[data.rfind(b"\n") + 1:]
        try:
            json.loads(tail)
        except ValueError:
            with open(self.path, "rb+") as f:
                f.truncate(data.rfind(b"\n") + 1)
        else:
            with open(self.path, "ab") as f:
                f.write(b"\n")


class FleetLog:
    """Shared lease/heartbeat JSONL for one fleet out-dir.

    Multiple processes append concurrently (coordinator + every collector);
    each record is one short ``O_APPEND`` line written in a single call,
    which local POSIX filesystems keep un-interleaved (network filesystems
    are not supported for concurrent appends — see the module docstring).
    Reads are *incremental*: the coordinator polls this log
    several times a second for the whole run, so each instance remembers its
    file offset and parses only bytes appended since the last read (a
    shrunken file — ``--force`` — resets the cache).  Only complete lines are
    consumed, which also handles the torn trailing line a killed writer (or
    an append racing this read) can leave."""

    def __init__(self, path: Union[str, pathlib.Path]):
        self.path = pathlib.Path(path)
        self._lock = threading.Lock()
        self._offset = 0
        self.corrupt_lines = 0  # malformed complete lines skipped so far
        self._parsed: List[dict] = []
        # (cycle, shard) -> newest heartbeat ts, maintained incrementally:
        # the coordinator asks per live shard every poll tick, and scanning
        # the whole log each time would grow quadratic over a long run
        self._last_hb: dict = {}

    def append(self, record: dict) -> dict:
        record.setdefault("ts", time.time())
        record.setdefault("pid", os.getpid())
        self.path.parent.mkdir(parents=True, exist_ok=True)
        plan = _fault_plan()
        garbage = plan.corrupt_line(f"log:{self.path.name}") if plan else None
        with open(self.path, "a") as f:
            if garbage is not None:
                f.write(garbage + "\n")
            f.write(json.dumps(record) + "\n")
            f.flush()
        return record

    def _refresh(self) -> None:
        try:
            size = os.path.getsize(self.path)
        except OSError:
            self._offset, self._parsed, self._last_hb = 0, [], {}
            self.corrupt_lines = 0
            return
        if size < self._offset:  # truncated/replaced: start over
            self._offset, self._parsed, self._last_hb = 0, [], {}
            self.corrupt_lines = 0
        if size == self._offset:
            return
        with open(self.path, "rb") as f:
            f.seek(self._offset)
            chunk = f.read()
        end = chunk.rfind(b"\n")
        if end < 0:  # no complete new line yet
            return
        self._offset += end + 1
        for line in chunk[:end + 1].splitlines():
            if not line.strip():
                continue
            try:
                record = json.loads(line)
            except json.JSONDecodeError:
                # foreign corruption; skip like the campaign loader, but tally
                self.corrupt_lines += 1
                continue
            self._parsed.append(record)
            if record.get("type") == "heartbeat":
                key = (record.get("cycle"), record.get("shard"))
                ts = float(record.get("ts", 0.0))
                if ts > self._last_hb.get(key, 0.0):
                    self._last_hb[key] = ts

    def records(self, type: Optional[str] = None,
                cycle: Optional[int] = None,
                shard: Optional[int] = None) -> List[dict]:
        """Log records filtered by type/cycle/shard, in append order."""
        with self._lock:  # reader cache is shared across threads
            self._refresh()
            snapshot = list(self._parsed)
        out = []
        for r in snapshot:
            if type is not None and r.get("type") != type:
                continue
            if cycle is not None and r.get("cycle") != cycle:
                continue
            if shard is not None and r.get("shard") != shard:
                continue
            out.append(r)
        return out

    def last_heartbeat(self, cycle: int, shard: int) -> Optional[float]:
        """Timestamp of the newest heartbeat for (cycle, shard), or None —
        an O(1) lookup against the incrementally maintained index."""
        with self._lock:
            self._refresh()
            return self._last_hb.get((cycle, shard))
