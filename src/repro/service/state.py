"""Resumable per-cycle state for the continuous tuning loop.

One JSONL line per *completed* cycle (the same durability model as the
campaign runner: a killed loop loses at most the in-flight cycle, and its
partially collected shard file resumes case-by-case anyway).  Each record
carries the cycle's full provenance — seed window, dataset growth, refit and
recommend latency, drift score, and the decision taken — so the state file
doubles as the loop's audit log.

Record schema (``STATE_SCHEMA_VERSION = 1``)::

    {
      "schema_version": 1,
      "cycle": 0,                      # 0-based cycle index (the resume key)
      "status": "ok",
      "campaign": "paper_core",
      "fast": true,
      "seeds": [1000, 1001],           # the cycle's seed window
      "n_executed": 26,                # cases run this cycle (0 after resume)
      "n_failures": 0,
      "n_records_merged": 52,          # records in merged.jsonl after merge
      "n_new_rows": 26,                # rows newly ingested by the autotuner
      "n_observations": 52,            # autotuner store size after ingest
      "refit": true,                   # did maybe_refit() fit a model
      "drift": 0.18,                   # median rel. error on new rows (null
                                       #   until a previous model existed)
      "refit_s": 0.41,
      "recommend_s": 0.007,
      "top": [{...top-k configs...}],  # ranked() report, predicted MB/s each
      "decision": {"reconfigure": true, "predicted_gain": 0.31,
                   "explore": false, "config": {...knobs...}},
      "current_config": {...knobs...}, # config in force AFTER this cycle
      "elapsed_s": 3.2,
      "host": "...", "timestamp": 1780000000.0
    }

``LoopState`` dedups by cycle keeping the latest record, tolerating the
torn-trailing-line artifacts of a killed writer (via the campaign loader).
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional, Union

from ..data.campaign import load_records

__all__ = ["STATE_SCHEMA_VERSION", "LoopState"]

STATE_SCHEMA_VERSION = 1


class LoopState:
    """Append-only JSONL cycle log with resume semantics."""

    def __init__(self, path: Union[str, pathlib.Path]):
        self.path = pathlib.Path(path)

    def cycles(self) -> List[dict]:
        """Completed cycle records, deduplicated by cycle (latest wins),
        ordered by cycle index."""
        latest: Dict[int, dict] = {}
        for r in load_records(self.path):
            if r.get("status") == "ok" and "cycle" in r:
                latest[int(r["cycle"])] = r
        return [latest[c] for c in sorted(latest)]

    def next_cycle(self) -> int:
        """First cycle index not yet completed (cycles run in order, so this
        is one past the highest completed index)."""
        done = self.cycles()
        return int(done[-1]["cycle"]) + 1 if done else 0

    def current_config(self) -> Optional[dict]:
        """The config in force after the last completed cycle — restored on
        resume so a killed loop keeps tuning from where it left off."""
        done = self.cycles()
        return dict(done[-1]["current_config"]) if done else None

    def append(self, record: dict) -> None:
        """Durably append one completed-cycle record."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(record) + "\n")
            f.flush()
