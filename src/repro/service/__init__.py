"""repro.service — the continuous tuning loop: collect -> merge -> refit ->
re-recommend, run as a resumable service (``python -m repro.service.loop``).

Converts the standalone campaign runner (``repro.data.campaign``), the
dataset merge CLI, and the ``OnlineAutotuner`` into one end-to-end system
that keeps growing the observation dataset and keeps the recommendation
fresh — the paper's "days -> minutes" claim, closed into a loop.

Submodules are imported lazily so ``python -m repro.service.loop`` doesn't
trigger runpy's double-import warning.
"""

__all__ = [
    "ContinuousTuningLoop",
    "LoopConfig",
    "DEFAULT_LOOP_DIR",
    "LoopState",
    "STATE_SCHEMA_VERSION",
]

_LOOP = ("ContinuousTuningLoop", "LoopConfig", "DEFAULT_LOOP_DIR", "main")
_STATE = ("LoopState", "STATE_SCHEMA_VERSION")


def __getattr__(name: str):
    if name in _LOOP:
        from . import loop
        return getattr(loop, name)
    if name in _STATE:
        from . import state
        return getattr(state, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
