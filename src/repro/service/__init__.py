"""repro.service — the continuous tuning loop: collect -> merge -> refit ->
re-recommend, run as a resumable service (``python -m repro.service.loop``),
its multi-host collection fleet (``python -m repro.service.fleet``), and the
concurrent recommendation-serving tier (``python -m repro.service.serve``).

Converts the standalone campaign runner (``repro.data.campaign``), the
dataset merge CLI, and the ``OnlineAutotuner`` into one end-to-end system
that keeps growing the observation dataset and keeps the recommendation
fresh — the paper's "days -> minutes" claim, closed into a loop.  The fleet
layer fans each cycle's collection out over leased campaign shards while
guaranteeing the merged dataset stays byte-identical to a single-host run
(see ``docs/fleet.md``); the serve layer answers /predict and /recommend
for many concurrent clients with micro-batched scoring, a refit-aware
response cache, and atomic model hot-swap (see ``docs/serving.md``).

Submodules are imported lazily so ``python -m repro.service.loop`` doesn't
trigger runpy's double-import warning.
"""

__all__ = [
    "ContinuousTuningLoop",
    "LoopConfig",
    "DEFAULT_LOOP_DIR",
    "FleetConfig",
    "FleetCoordinator",
    "DEFAULT_FLEET_DIR",
    "LoopState",
    "FleetLog",
    "STATE_SCHEMA_VERSION",
    "RecommendationService",
    "ServeConfig",
    "ResponseCache",
    "MicroBatcher",
    "DEFAULT_SERVE_DIR",
]

_LOOP = ("ContinuousTuningLoop", "LoopConfig", "DEFAULT_LOOP_DIR", "main")
_FLEET = ("FleetConfig", "FleetCoordinator", "DEFAULT_FLEET_DIR",
          "run_collector", "collector_shard_path", "synthetic_executor")
_STATE = ("LoopState", "FleetLog", "STATE_SCHEMA_VERSION")
_SERVE = ("RecommendationService", "ServeConfig", "ResponseCache",
          "MicroBatcher", "context_key", "DEFAULT_SERVE_DIR")


def __getattr__(name: str):
    if name in _LOOP:
        from . import loop
        return getattr(loop, name)
    if name in _FLEET:
        from . import fleet
        return getattr(fleet, name)
    if name in _STATE:
        from . import state
        return getattr(state, name)
    if name in _SERVE:
        from . import serve
        return getattr(serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
