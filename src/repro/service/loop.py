"""Continuous collect -> merge -> refit -> re-recommend service (the paper's
"days of trial-and-error -> minutes of prediction" claim, closed into a loop).

Each *cycle*:

1. **collect** — run a batch of campaign cases with a fresh seed window
   (``run_campaign_batch``), appending to this cycle's shard JSONL; the
   dataset grows past the paper's 141 rows toward its 500-1000 target.
2. **merge**  — dedup all shard files into ``merged.jsonl``
   (``merge_files``), the loop's canonical dataset.
3. **refit**  — ingest only the *new* records into the ``OnlineAutotuner``'s
   zero-copy column store (``ingest_records``) and refit on schedule or when
   the drift score (median relative error on the new rows) exceeds the
   threshold.
4. **re-recommend** — rank the candidate grid under the live context
   (``ranked``), take an ``AutotuneDecision`` against the config currently in
   force, and adopt the proposal when the predicted gain clears the bar.

Every completed cycle appends one provenance record to a resumable JSONL
state file (``service/state.py``): a killed loop restarts at its last
completed cycle, and a cycle killed mid-collection resumes case-by-case
inside its shard file.

CLI::

    python -m repro.service.loop --fast                  # run (resumes)
    python -m repro.service.loop --fast --cycles 6       # grow further
    python -m repro.service.loop --status                # audit cycle log
    python -m repro.service.loop --force --fast          # start over

To run the *collect* step on many processes/hosts at once, see the fleet
coordinator (``python -m repro.service.fleet``, ``docs/fleet.md``): it reuses
this module's cycle tail (merge -> refit -> re-recommend) unchanged while
fanning collection out over leased campaign shards.
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import pathlib
import socket
import sys
import time
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from ..core.autotune import DEFAULT_SPACE, KNOB_NAMES, ConfigSpace, OnlineAutotuner
from ..core.features import TARGET_NAME
from ..core.transfer import AffineCalibrator
from ..data.campaign import (
    RunContext,
    RunResult,
    canonical_records,
    case_index,
    completed_keys,
    load_records,
    merge_files,
    rows_from_records,
    run_campaign_batch,
)
from ..data.registry import Campaign
from ._cli import add_chaos_args, add_tuning_args, chaos_plan_from_args
from .state import STATE_SCHEMA_VERSION, ZERO_FAULTS, ZERO_TRANSFER, LoopState

__all__ = ["LoopConfig", "ContinuousTuningLoop", "main", "DEFAULT_LOOP_DIR",
           "add_tuning_args", "config_kwargs_from_args"]

DEFAULT_LOOP_DIR = pathlib.Path("/tmp/repro_io/loop")


@dataclasses.dataclass
class LoopConfig:
    """Knobs of the continuous tuning loop (CLI flags mirror these)."""

    campaign: Union[str, Campaign] = "paper_core"
    cycles: int = 3                      # total cycles the state file targets
    seeds_per_cycle: int = 1             # campaign passes per cycle
    base_seed: int = 1000
    seed_stride: int = 100               # cycle c uses seeds [base + c*stride, ...)
    fast: bool = False                   # CI-sized campaign subsets
    out_dir: pathlib.Path = DEFAULT_LOOP_DIR
    model: str = "xgboost"
    space: ConfigSpace = DEFAULT_SPACE
    top_k: int = 5
    refit_every: int = 20                # observations between scheduled refits
    min_observations: int = 24
    gain_threshold: float = 0.10
    drift_threshold: float = 0.5
    seed: int = 0                        # model seed (decisions deterministic)
    # Collection hardening (docs/robustness.md): threaded into every
    # run_campaign_batch call this loop (or its fleet subclass) makes.
    case_deadline_s: Optional[float] = None  # per-case wall-clock deadline
    max_retries: int = 2                 # transient-failure retries per case
    backoff_s: float = 0.05              # base of the exponential backoff
    quarantine_after: Optional[int] = 3  # permanent failures before quarantine
    # Cross-backend transfer (docs/transfer.md): a cycle whose rows include a
    # never-before-seen backend profile triggers a few-shot residual
    # calibration from at most this many of the new backend's rows INSTEAD of
    # a full refit that cycle (0 disables calibration entirely).
    calibration_k: int = 25

    def __post_init__(self):
        self.out_dir = pathlib.Path(self.out_dir)
        if self.seeds_per_cycle > self.seed_stride:
            raise ValueError("seeds_per_cycle must be <= seed_stride "
                             "(seed windows would overlap across cycles)")


class ContinuousTuningLoop:
    """Drives repeated collect -> merge -> refit -> re-recommend cycles.

    ``executor`` overrides campaign case execution (tests); ``progress`` gets
    one-line status strings.  All state that matters for resume lives on
    disk — a fresh instance pointed at the same ``out_dir`` continues where
    the previous process stopped, rebuilding the in-memory predictor by
    re-ingesting the merged dataset.
    """

    def __init__(
        self,
        cfg: LoopConfig,
        executor: Optional[Callable] = None,
        progress: Optional[Callable[[str], None]] = None,
    ):
        self.cfg = cfg
        self.state = LoopState(cfg.out_dir / "loop_state.jsonl")
        self.shards_dir = cfg.out_dir / "shards"
        self.merged_path = cfg.out_dir / "merged.jsonl"
        self._executor = executor
        self._progress = progress
        self._ctx = RunContext()
        self._case_order: Optional[dict] = None  # case_id -> campaign position
        self.merge_corrupt_lines = 0    # malformed shard lines at last merge
        self._rejected_keys: set = set()  # keys refused by the refit guard
        self._known_profiles: set = set()  # backend profiles seen in rows
        self.calibrators: dict = {}     # backend -> AffineCalibrator
        self.tuner = OnlineAutotuner(
            space=cfg.space,
            refit_every=cfg.refit_every,
            min_observations=cfg.min_observations,
            gain_threshold=cfg.gain_threshold,
            drift_threshold=cfg.drift_threshold,
            model=cfg.model,
            seed=cfg.seed,
        )

    # ------------------------------------------------------------------
    def _log(self, msg: str) -> None:
        if self._progress is not None:
            self._progress(msg)

    def _cycle_seeds(self, cycle: int) -> List[int]:
        start = self.cfg.base_seed + cycle * self.cfg.seed_stride
        return list(range(start, start + self.cfg.seeds_per_cycle))

    def _shard_path(self, cycle: int) -> pathlib.Path:
        return self.shards_dir / f"cycle_{cycle:04d}.jsonl"

    def _shard_files(self) -> List[pathlib.Path]:
        # both layouts: flat per-cycle files (single host) and per-host
        # subdirectories (fleet collectors); the canonical merge makes the
        # result independent of which produced them
        return (sorted(self.shards_dir.glob("cycle_*.jsonl"))
                + sorted(self.shards_dir.glob("host_*/cycle_*.jsonl")))

    def _cycle_shard_files(self, cycle: int) -> List[pathlib.Path]:
        """Every shard file holding cycle ``cycle``'s records, either layout."""
        name = f"cycle_{cycle:04d}.jsonl"
        paths = [self.shards_dir / name] + sorted(self.shards_dir.glob(f"host_*/{name}"))
        return [p for p in paths if p.exists()]

    def _repair_specs(self, cycle: int) -> List[tuple]:
        """(shard_file, (h, H)) pairs to re-run failed cases against — the
        shard spec must match collection so resume keys line up."""
        return [(self._shard_path(cycle), (0, 1))]

    def _case_positions(self) -> dict:
        if self._case_order is None:
            self._case_order = case_index(self.cfg.campaign, self.cfg.fast)
        return self._case_order

    def _default_config(self) -> dict:
        return {k: getattr(self.cfg.space, k)[0] for k in KNOB_NAMES}

    @staticmethod
    def _knobs_only(config: dict) -> dict:
        return {k: config[k] for k in KNOB_NAMES if k in config}

    def _merge(self) -> List[dict]:
        shards = self._shard_files()
        if not shards:
            return []
        counters: dict = {}
        _, merged = merge_files(shards, self.merged_path,
                                index=self._case_positions(),
                                counters=counters)
        self.merge_corrupt_lines = counters.get("corrupt_lines", 0)
        return merged

    def _validate_records(self, records: List[dict]) -> tuple:
        """Refit validation guard: refuse observation rows that would poison
        the model — any non-finite feature, or a non-finite/negative target.

        Returns ``(clean_records, n_rejected)`` where ``n_rejected`` counts
        only *newly seen* poisoned keys (a bad row sitting in the merged
        dataset is rejected again every cycle, but reported once)."""
        clean: List[dict] = []
        n_rejected = 0
        for r in records:
            if r.get("status") == "ok" and r.get("row"):
                row = r["row"]
                tgt = float(row.get(TARGET_NAME, 0.0))
                bad = not math.isfinite(tgt) or tgt < 0 or any(
                    isinstance(v, (int, float)) and not math.isfinite(float(v))
                    for v in row.values()
                )
                if bad:
                    key = (r.get("case_id"), r.get("rep", 0), r.get("seed", 0))
                    if key not in self._rejected_keys:
                        self._rejected_keys.add(key)
                        n_rejected += 1
                    continue
            clean.append(r)
        if n_rejected:
            self._log(f"refit guard: rejected {n_rejected} poisoned row(s)")
        return clean, n_rejected

    def _transfer_step(self, cycle_rows: List[dict]) -> dict:
        """Detect never-before-seen backend profiles in this cycle's rows
        and few-shot-calibrate for them instead of a full refit.

        A new backend's rows land outside the fitted model's training
        distribution; tree models cannot extrapolate, so their drift score
        would force a full refit — on a handful of rows that would mostly
        relearn what the model already knows.  Instead, an affine residual
        correction in log1p space is fitted from at most
        ``cfg.calibration_k`` of the new backend's rows
        (``core.transfer.AffineCalibrator``) and the scheduled refit is
        skipped for the cycle.  The correction is monotone, so the ranked
        recommendation order is unchanged — only absolute predictions move.
        Returns the cycle record's ``transfer`` provenance block.

        Deterministic replay contract: ``_warm_start`` re-runs this method
        on exactly the rows the live cycle saw, so a resumed loop rebuilds
        the same ``_known_profiles`` set, the same calibrators, and the same
        skipped-refit schedule as the uninterrupted run."""
        seen = sorted({str(r["backend"]) for r in cycle_rows
                       if r.get("backend")})
        new = [b for b in seen if b not in self._known_profiles]
        self._known_profiles.update(seen)
        block = {**ZERO_TRANSFER, "new_profiles": new,
                 "known_profiles": len(self._known_profiles),
                 "calibrations": {}}
        if not new or not self.tuner.fitted or self.cfg.calibration_k <= 0:
            return block
        n_rows = 0
        calibrations = {}
        for backend in new:
            rows = [r for r in cycle_rows
                    if str(r.get("backend")) == backend
                    ][: self.cfg.calibration_k]
            if not rows:
                continue
            preds = np.asarray(
                [self.tuner.predictor.predict_throughput(r) for r in rows])
            actual = np.asarray(
                [float(r.get(TARGET_NAME, 0.0)) for r in rows])
            cal = AffineCalibrator().fit(
                None, np.log1p(np.maximum(preds, 0.0)), np.log1p(actual))
            self.calibrators[backend] = cal
            calibrations[backend] = cal.as_dict()
            n_rows += len(rows)
        if n_rows:
            block.update(calibrated=True, calibration_rows=n_rows,
                         calibrations=calibrations)
            self._log(f"transfer: new backend profile(s) {new} — "
                      f"calibrated on {n_rows} row(s) instead of refitting")
        return block

    def _repair_shards(self, upto: int) -> int:
        """Re-run failed cases of already-completed cycles.

        Campaign resume semantics inside each shard file re-run exactly the
        (case, rep, seed) keys that never succeeded, so transient benchmark
        crashes heal on the next invocation instead of leaving the dataset
        permanently short.  Returns the number of cases re-executed."""
        n = 0
        for cycle in range(upto):
            for shard, shard_spec in self._repair_specs(cycle):
                if not shard.exists():
                    continue
                records = load_records(shard)
                done = completed_keys(records)
                unresolved = any(
                    r.get("status") == "error"
                    and (r.get("case_id"), r.get("rep", 0), r.get("seed", 0)) not in done
                    for r in records
                )
                if not unresolved:
                    continue
                results = run_campaign_batch(
                    self.cfg.campaign, shard, self._cycle_seeds(cycle),
                    fast=self.cfg.fast, shard=shard_spec, ctx=self._ctx,
                    executor=self._executor, progress=self._progress,
                    deadline_s=self.cfg.case_deadline_s,
                    max_retries=self.cfg.max_retries,
                    backoff_s=self.cfg.backoff_s,
                    quarantine_after=self.cfg.quarantine_after,
                )
                n += sum(r.n_executed for r in results)
        if n:
            self._log(f"repair: re-ran {n} previously failed case(s)")
        return n

    def _warm_start(self, upto: int) -> None:
        """Rebuild predictor state from already-collected shards (resume).

        Replays the completed cycles' ingest/refit sequence shard by shard —
        one ``ingest_records`` + ``maybe_refit`` per cycle, in cycle order —
        so the resumed model, its ``refit_every`` schedule position, and the
        drift bookkeeping all match the uninterrupted run exactly.  Past
        explore proposals (from the state file) are replayed too, so the
        cold-start exploration sequence continues instead of restarting."""
        n = 0
        for cycle in range(upto):
            records = [r for p in self._cycle_shard_files(cycle)
                       for r in load_records(p)]
            if not records:
                continue
            # canonical order == single-host execution order, so the replay
            # is identical no matter how many collectors produced the cycle;
            # the same validation guard as the live path keeps the resumed
            # model identical to the uninterrupted run's
            canon = canonical_records(records, self._case_positions())
            clean, _ = self._validate_records(canon)
            n += self.tuner.ingest_records(clean)
            # replay the transfer step on the same rows the live cycle saw:
            # a cycle that calibrated instead of refitting must skip the
            # refit here too, or the resumed model drifts off the original
            transfer = self._transfer_step(rows_from_records(canon))
            if not transfer["calibrated"]:
                self.tuner.maybe_refit()
        for rec in self.state.cycles():
            decision = rec.get("decision") or {}
            if decision.get("explore") and decision.get("config"):
                self.tuner.mark_explored(decision["config"])
        if n:
            self._merge()  # keep merged.jsonl fresh for external readers
            self._log(f"warm-start: {n} rows re-ingested from "
                      f"{upto} completed cycle(s), fitted={self.tuner.fitted}")

    def _live_context(self, all_rows: List[dict], cycle_rows: List[dict]) -> dict:
        """Workload descriptors for ``decide()``/``ranked()``: medians of the
        merged dataset's exogenous features, plus the freshest measured
        delivery rate as the 'current throughput' reference."""

        def med(key: str, rows: List[dict]) -> float:
            vals = [float(r.get(key, 0.0)) for r in rows
                    if float(r.get(key, 0.0)) > 0]
            return float(np.median(vals)) if vals else 0.0

        return {
            "file_size_mb": med("file_size_mb", all_rows),
            "n_samples": med("n_samples", all_rows),
            "throughput_mb_s": med(TARGET_NAME, cycle_rows or all_rows),
        }

    # ------------------------------------------------------------------
    def _collect(self, cycle: int, seeds: List[int]) -> dict:
        """Collect this cycle's observations; returns collection stats.

        The single-host implementation runs the whole campaign into one flat
        shard file.  ``FleetCoordinator`` overrides this to lease campaign
        shards to collector processes (``docs/fleet.md``); everything after
        collection — merge, refit, re-recommend — is shared."""
        results: List[RunResult] = run_campaign_batch(
            self.cfg.campaign, self._shard_path(cycle), seeds,
            fast=self.cfg.fast, ctx=self._ctx, executor=self._executor,
            progress=self._progress,
            deadline_s=self.cfg.case_deadline_s,
            max_retries=self.cfg.max_retries,
            backoff_s=self.cfg.backoff_s,
            quarantine_after=self.cfg.quarantine_after,
        )
        n_executed = sum(r.n_executed for r in results)
        n_failures = sum(len(r.failures) for r in results)
        return {
            "n_executed": n_executed,
            "n_failures": n_failures,
            "collectors": 1,
            "releases": 0,
            "hosts": {"host_0": {"host": self._ctx.host,
                                 "n_executed": n_executed,
                                 "n_failures": n_failures,
                                 "releases": 0}},
            "faults": {
                "retried": sum(r.retried for r in results),
                "timeouts": sum(r.n_timeouts for r in results),
                "quarantined": sum(r.n_quarantined for r in results),
                "write_retries": sum(r.write_retries for r in results),
            },
        }

    def run_cycle(self, cycle: int, current_config: dict) -> dict:
        """One full collect -> merge -> refit -> re-recommend cycle."""
        t_cycle = time.perf_counter()
        seeds = self._cycle_seeds(cycle)

        # 1. collect: this cycle's shard file(s); killed runs resume per case
        collect = self._collect(cycle, seeds)
        n_executed = collect["n_executed"]
        n_failures = collect["n_failures"]

        # 2. merge: all shards -> the canonical deduplicated dataset
        merged = self._merge()
        all_rows = rows_from_records(merged)
        seed_set = set(seeds)
        cycle_rows = rows_from_records(
            [r for r in merged if r.get("seed") in seed_set])

        # 3. refit: zero-copy ingest of the new rows, drift-aware schedule —
        # behind the validation guard that refuses poisoned observations.
        # A cycle whose rows introduce a never-before-seen backend profile
        # calibrates few-shot instead of refitting (docs/transfer.md).
        clean, n_rejected = self._validate_records(merged)
        n_new = self.tuner.ingest_records(clean)
        t0 = time.perf_counter()
        transfer = self._transfer_step(cycle_rows)
        if transfer["calibrated"]:
            refit = False  # calibration replaces this cycle's refit
        else:
            refit = self.tuner.maybe_refit()
        refit_s = time.perf_counter() - t0
        drift = self.tuner.last_drift

        # 4. re-recommend: ranked report + decision against the live config
        # (decide reuses the ranked winner — one grid inference per cycle)
        context = self._live_context(all_rows, cycle_rows)
        t0 = time.perf_counter()
        top = self.tuner.ranked(context, top_k=self.cfg.top_k)
        # Poisoned-cycle circuit breaker: a refit that predicts garbage
        # (non-finite scores) is rolled back to the previous generation and
        # the grid is re-ranked on the restored model.
        rollback = False
        if top and any(
            not math.isfinite(float(t.get("predicted_throughput_mb_s", 0.0)))
            for t in top
        ):
            if self.tuner.rollback():
                rollback = True
                self._log(f"cycle {cycle}: non-finite predictions — rolled "
                          f"back to generation {self.tuner.generation}")
                top = self.tuner.ranked(context, top_k=self.cfg.top_k)
        decision = self.tuner.decide(current_config, context,
                                     best=top[0] if top else None)
        recommend_s = time.perf_counter() - t0

        explore = bool(decision.config and decision.config.get("explore"))
        if decision.reconfigure and not explore:
            new_config = self._knobs_only(decision.config)
        else:
            # exploration proposals come from cold-start candidate cycling,
            # not the model — the loop's batch collection already explores,
            # so only model-backed (exploit) proposals are adopted
            new_config = dict(current_config)

        self._log(
            f"cycle {cycle}: +{n_new} rows (n={self.tuner.n_observations}) "
            f"refit={refit} ({refit_s * 1e3:.0f}ms) "
            f"drift={'n/a' if math.isnan(drift) else f'{drift:.2f}'} "
            f"recommend={recommend_s * 1e3:.1f}ms "
            f"gain={decision.predicted_gain:+.0%} "
            f"reconfigure={decision.reconfigure and not explore}"
        )

        return {
            "schema_version": STATE_SCHEMA_VERSION,
            "cycle": cycle,
            "status": "ok",
            "campaign": (self.cfg.campaign if isinstance(self.cfg.campaign, str)
                         else self.cfg.campaign.name),
            "fast": self.cfg.fast,
            "seeds": seeds,
            "n_executed": n_executed,
            "n_failures": n_failures,
            "collectors": collect["collectors"],
            "releases": collect["releases"],
            "hosts": collect["hosts"],
            "n_records_merged": len(merged),
            "n_new_rows": n_new,
            "n_observations": self.tuner.n_observations,
            "refit": refit,
            "drift": None if math.isnan(drift) else round(drift, 6),
            "refit_s": round(refit_s, 6),
            "recommend_s": round(recommend_s, 6),
            "top": top,
            "decision": {
                "reconfigure": bool(decision.reconfigure and not explore),
                "explore": explore,
                "predicted_gain": round(float(decision.predicted_gain), 6),
                "config": self._knobs_only(decision.config or {}),
            },
            "faults": {
                **dict(ZERO_FAULTS),
                **{k: int(v) for k, v in
                   (collect.get("faults") or {}).items()},
                "corrupt_lines": self.merge_corrupt_lines,
                "rejected_rows": n_rejected,
                "rollback": rollback,
            },
            "transfer": transfer,
            "current_config": new_config,
            "elapsed_s": round(time.perf_counter() - t_cycle, 6),
            "host": socket.gethostname(),
            "timestamp": time.time(),
        }

    def run(self, max_cycles: Optional[int] = None) -> List[dict]:
        """Run (or resume) cycles until ``cfg.cycles`` are complete.

        ``max_cycles`` bounds how many cycles *this invocation* runs — the
        kill-between-cycles hook; a later call (or process) picks up the rest.
        Returns the cycle records completed by this invocation."""
        start = self.state.next_cycle()
        end = self.cfg.cycles
        if max_cycles is not None:
            end = min(end, start + max_cycles)
        # repair runs even when every cycle is complete — a failure in the
        # *last* cycle must still heal on the next invocation.  The re-merge
        # is unconditional: merged.jsonl is derived state, and rebuilding it
        # from the shard files also heals a torn or corrupted merge output.
        if start > 0:
            self._repair_shards(start)
            self._merge()
        if start >= end:
            return []
        current = self.state.current_config() or self._default_config()
        if start > 0:
            self._warm_start(start)
        completed: List[dict] = []
        for cycle in range(start, end):
            record = self.run_cycle(cycle, current)
            self.state.append(record)
            current = record["current_config"]
            completed.append(record)
        return completed


# ---------------------------------------------------------------- CLI

def _format_status(cycles: List[dict], state_corrupt_lines: int = 0) -> str:
    if not cycles:
        return "no completed cycles"
    hdr = (f"{'cycle':>5s} {'rows':>6s} {'new':>5s} {'hosts':>6s} {'refit':>5s} "
           f"{'drift':>7s} {'refit_ms':>8s} {'rec_ms':>7s} {'gain':>7s} {'config':s}")
    lines = [hdr, "-" * len(hdr)]
    for r in cycles:
        drift = r.get("drift")
        cfg = r.get("current_config", {})
        abbrev = {"batch_size": "bs", "num_workers": "w", "block_kb": "kb",
                  "n_threads": "t", "prefetch_depth": "pf"}
        cfg_s = ",".join(f"{abbrev.get(k, k)}{v}" for k, v in cfg.items())
        hosts_s = str(r.get("collectors", 1))
        if r.get("releases"):
            hosts_s += f"+{r['releases']}r"  # shards re-leased after a crash
        lines.append(
            f"{r['cycle']:>5d} {r['n_observations']:>6d} {r['n_new_rows']:>5d} "
            f"{hosts_s:>6s} "
            f"{str(r['refit']):>5s} {'n/a' if drift is None else f'{drift:.2f}':>7s} "
            f"{r['refit_s'] * 1e3:>8.1f} {r['recommend_s'] * 1e3:>7.1f} "
            f"{r['decision']['predicted_gain']:>+6.0%} {cfg_s}"
        )
    # per-host provenance aggregated over the cycle log (schema v2; v1
    # records are upgraded by LoopState so this renders for old files too)
    agg: dict = {}
    for r in cycles:
        for slot, h in (r.get("hosts") or {}).items():
            a = agg.setdefault(slot, {"host": h.get("host", ""),
                                      "n_executed": 0, "n_failures": 0,
                                      "releases": 0})
            a["host"] = h.get("host", "") or a["host"]
            a["n_executed"] += int(h.get("n_executed", 0))
            a["n_failures"] += int(h.get("n_failures", 0))
            a["releases"] += int(h.get("releases", 0))
    if agg:
        lines.append("per-host provenance:")
        # numeric-aware: host_10 sorts after host_9, not after host_1
        def slot_key(s):
            tail = s.rsplit("_", 1)[-1]
            return (0, int(tail)) if tail.isdigit() else (1, tail)
        for slot in sorted(agg, key=slot_key):
            a = agg[slot]
            lines.append(f"  {slot}: host={a['host'] or '?'} "
                         f"executed={a['n_executed']} failures={a['n_failures']} "
                         f"releases={a['releases']}")
    # fault provenance aggregated over the cycle log (schema v3; older
    # records upgrade to a zeroed block, so this never KeyErrors)
    totals = {k: 0 for k in ZERO_FAULTS if k != "rollback"}
    rollbacks = 0
    for r in cycles:
        f = r.get("faults") or {}
        for k in totals:
            totals[k] += int(f.get(k, 0))
        rollbacks += bool(f.get("rollback"))
    totals["corrupt_lines"] += int(state_corrupt_lines)
    if rollbacks or any(totals.values()):
        lines.append("faults: " + " ".join(f"{k}={v}" for k, v
                                           in totals.items())
                     + f" rollbacks={rollbacks}")
    # transfer provenance aggregated over the cycle log (schema v4; older
    # records upgrade to an all-clear block, so this never KeyErrors)
    calibrated_cycles = 0
    calibration_rows = 0
    profiles: set = set()
    for r in cycles:
        t = r.get("transfer") or {}
        calibrated_cycles += bool(t.get("calibrated"))
        calibration_rows += int(t.get("calibration_rows", 0))
        profiles.update(t.get("new_profiles") or [])
    if calibrated_cycles:
        lines.append(f"transfer: profiles={len(profiles)} "
                     f"calibrated_cycles={calibrated_cycles} "
                     f"calibration_rows={calibration_rows}")
    return "\n".join(lines)


def config_kwargs_from_args(args: argparse.Namespace) -> dict:
    """LoopConfig keyword arguments from an ``add_tuning_args`` namespace."""
    return dict(
        campaign=args.campaign, cycles=args.cycles,
        seeds_per_cycle=args.seeds_per_cycle, base_seed=args.base_seed,
        fast=args.fast, out_dir=args.out_dir, model=args.model,
        top_k=args.top_k, refit_every=args.refit_every,
        min_observations=args.min_observations,
        gain_threshold=args.gain_threshold,
        drift_threshold=args.drift_threshold,
        calibration_k=args.calibration_k,
        case_deadline_s=args.case_deadline,
        max_retries=args.max_retries,
        quarantine_after=(None if args.quarantine_after <= 0
                          else args.quarantine_after),
    )


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service.loop",
        description="Continuous collect -> merge -> refit -> re-recommend "
                    "tuning loop (resumable, single host; see "
                    "repro.service.fleet for multi-collector runs).",
    )
    add_tuning_args(ap)
    add_chaos_args(ap)
    ap.add_argument("--out-dir", type=pathlib.Path, default=DEFAULT_LOOP_DIR,
                    help="state + shard directory (resume key)")
    args = ap.parse_args(argv)

    chaos_plan_from_args(args)
    cfg = LoopConfig(**config_kwargs_from_args(args))
    loop = ContinuousTuningLoop(cfg, progress=lambda m: print(f"[loop] {m}"))

    if args.status:
        cycles = loop.state.cycles()
        print(_format_status(cycles, loop.state.corrupt_lines))
        return 0

    if args.force:
        loop.state.path.unlink(missing_ok=True)
        loop.merged_path.unlink(missing_ok=True)
        for p in loop._shard_files():
            p.unlink()

    start = loop.state.next_cycle()
    if 0 < start < cfg.cycles:
        print(f"[loop] resuming at cycle {start}/{cfg.cycles}")

    completed = loop.run(max_cycles=args.max_cycles)
    if not completed and start >= cfg.cycles:
        print(f"[loop] all {cfg.cycles} cycles already complete "
              f"(state: {loop.state.path}); use --cycles to extend or --force "
              "to restart")
    cycles = loop.state.cycles()
    print(_format_status(cycles, loop.state.corrupt_lines))
    n_failures = sum(r["n_failures"] for r in completed)
    if n_failures:
        print(f"[loop] {n_failures} case failure(s) recorded; they re-run on "
              "the next invocation", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
