"""Coordinator half of the collection fleet (see ``fleet.py`` for the
architecture and the byte-identical-merge invariant).

Split out of ``fleet.py`` so the collector role never imports it: this
module pulls in ``loop.py`` and therefore the jax model stack, which a
per-cycle spawned I/O worker has no business paying for."""

from __future__ import annotations

import dataclasses
import os
import pathlib
import subprocess
import sys
import time
from typing import Callable, Dict, List, Optional

from ..data.campaign import completed_keys, load_records
from .fleet import (
    DEFAULT_FLEET_DIR,
    _configured_executor,
    collector_shard_path,
    run_collector,
)
from .loop import ContinuousTuningLoop, LoopConfig, _format_status, config_kwargs_from_args
from .state import FleetLog

__all__ = ["FleetConfig", "FleetCoordinator", "coordinator_main"]


@dataclasses.dataclass
class FleetConfig(LoopConfig):
    """LoopConfig plus the fleet's topology/supervision knobs."""

    collectors: int = 2              # worker processes == campaign shards
    heartbeat_timeout_s: float = 60.0  # silence after which a live worker is stale
    heartbeat_every_s: float = 5.0   # collector liveness-tick cadence
    poll_interval_s: float = 0.2     # coordinator supervision cadence
    max_leases: int = 3              # lease attempts per shard per cycle
    executor_kind: str = "real"      # "real" I/O or "synthetic" dry-run rows
    sleep_per_case: float = 0.0      # pacing sleep (scaling experiments/tests)

    def __post_init__(self):
        super().__post_init__()
        if self.collectors < 1:
            raise ValueError("collectors must be >= 1")
        if self.executor_kind not in ("real", "synthetic"):
            raise ValueError(f"unknown executor kind {self.executor_kind!r}")


class _SubprocessCollector:
    """Default collector handle: a real ``--role collector`` child process."""

    def __init__(self, argv: List[str], env: dict, log_path: pathlib.Path):
        log_path.parent.mkdir(parents=True, exist_ok=True)
        self._logf = open(log_path, "w")
        self._proc = subprocess.Popen(argv, env=env, stdout=self._logf,
                                      stderr=subprocess.STDOUT)
        self.pid = self._proc.pid

    def poll(self) -> Optional[int]:
        rc = self._proc.poll()
        if rc is not None and not self._logf.closed:
            self._logf.close()
        return rc

    def kill(self) -> None:
        try:
            self._proc.kill()
            self._proc.wait(timeout=10)
        except (OSError, subprocess.SubprocessError):
            pass
        if not self._logf.closed:
            self._logf.close()


@dataclasses.dataclass
class _Lease:
    shard: int
    attempt: int
    handle: object
    started: float  # wall clock, comparable with heartbeat timestamps


class FleetCoordinator(ContinuousTuningLoop):
    """Drives fleet cycles: lease -> supervise -> re-lease -> merge/refit.

    Only the *collect* step differs from :class:`ContinuousTuningLoop` —
    shards run in collector processes under lease supervision; merge, refit,
    re-recommend, resume, warm-start, and repair are all inherited.  ``spawn``
    overrides how a lease becomes a worker (tests inject in-process fakes);
    the default spawns ``python -m repro.service.fleet --role collector``
    subprocesses with per-worker log files under ``<out_dir>/logs/``."""

    def __init__(
        self,
        cfg: FleetConfig,
        executor: Optional[Callable] = None,
        progress: Optional[Callable[[str], None]] = None,
        spawn: Optional[Callable] = None,
    ):
        super().__init__(cfg, executor=_configured_executor(cfg, executor),
                         progress=progress)
        self.cfg: FleetConfig = cfg
        self.fleet_log = FleetLog(cfg.out_dir / "fleet_state.jsonl")
        self._spawn = spawn or self._spawn_subprocess

    # -- leasing -------------------------------------------------------
    def _cycle_collectors(self, cycle: int) -> int:
        """Collector count the cycle was actually collected with (from its
        state record) — a fleet resumed with a different ``--collectors``
        must repair old cycles under their original shard split, or shards
        beyond the new count would never heal."""
        for rec in self.state.cycles():
            if rec.get("cycle") == cycle:
                return int(rec.get("collectors", self.cfg.collectors))
        return self.cfg.collectors

    def _repair_specs(self, cycle: int) -> List[tuple]:
        n = self._cycle_collectors(cycle)
        return [(collector_shard_path(self.cfg.out_dir, i, cycle), (i, n))
                for i in range(n)]

    def _spawn_subprocess(self, shard: int, cycle: int, attempt: int):
        if not isinstance(self.cfg.campaign, str):
            raise ValueError(
                "subprocess collectors need a registered campaign name; "
                "pass spawn= to run ad-hoc Campaign objects in-process")
        argv = [
            sys.executable, "-m", "repro.service.fleet", "--role", "collector",
            "--campaign", self.cfg.campaign,
            "--out-dir", str(self.cfg.out_dir),
            "--cycle", str(cycle),
            "--shard", f"{shard}/{self.cfg.collectors}",
            "--seeds", *map(str, self._cycle_seeds(cycle)),
            "--attempt", str(attempt),
        ]
        if self.cfg.fast:
            argv.append("--fast")
        if self.cfg.executor_kind != "real":
            argv += ["--executor", self.cfg.executor_kind]
        if self.cfg.sleep_per_case:
            argv += ["--sleep-per-case", str(self.cfg.sleep_per_case)]
        argv += ["--heartbeat-every", str(self.cfg.heartbeat_every_s)]
        if self.cfg.case_deadline_s is not None:
            argv += ["--case-deadline", str(self.cfg.case_deadline_s)]
        argv += ["--max-retries", str(self.cfg.max_retries),
                 "--quarantine-after",
                 str(self.cfg.quarantine_after or 0)]
        # an active fault plan rides along in env (REPRO_FAULT_PLAN), so
        # collectors inject from the same seeded schedule as the coordinator
        env = dict(os.environ)
        src = str(pathlib.Path(__file__).resolve().parents[2])
        env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else src)
        log_path = (self.cfg.out_dir / "logs"
                    / f"collector_c{cycle:04d}_s{shard}_a{attempt}.log")
        return _SubprocessCollector(argv, env, log_path)

    def _lease(self, leases: Dict[int, _Lease], shard: int, cycle: int,
               attempt: int) -> None:
        handle = self._spawn(shard, cycle, attempt)
        self.fleet_log.append({
            "type": "lease", "cycle": cycle, "shard": shard,
            "attempt": attempt, "collectors": self.cfg.collectors,
            "worker_pid": getattr(handle, "pid", None),
        })
        leases[shard] = _Lease(shard, attempt, handle, time.time())
        self._log(f"cycle {cycle}: leased shard {shard}/{self.cfg.collectors}"
                  f" (attempt {attempt})")

    def _relet_or_fail(self, leases: Dict[int, _Lease], lease: _Lease,
                       cycle: int, why: str) -> int:
        """Handle a dead/stale lease: re-lease the shard or give up."""
        attempt = lease.attempt + 1
        if attempt >= self.cfg.max_leases:
            raise RuntimeError(
                f"cycle {cycle} shard {lease.shard}: {why}; giving up after "
                f"{self.cfg.max_leases} lease attempts (completed cases are "
                "kept — re-running the fleet resumes this cycle)")
        self._log(f"cycle {cycle}: shard {lease.shard} {why} -> re-leasing")
        self._lease(leases, lease.shard, cycle, attempt)
        return 1

    # -- the overridden collect step ----------------------------------
    def _collect(self, cycle: int, seeds: List[int]) -> dict:
        n = self.cfg.collectors
        hosts = {f"host_{i}": {"host": "", "n_executed": 0,
                               "n_failures": 0, "releases": 0}
                 for i in range(n)}
        executed: Dict[int, int] = {i: 0 for i in range(n)}
        releases = 0
        leases: Dict[int, _Lease] = {}
        try:
            for i in range(n):
                self._lease(leases, i, cycle, attempt=0)
            while leases:
                for shard, lease in list(leases.items()):
                    rc = lease.handle.poll()
                    if rc is None:
                        hb = self.fleet_log.last_heartbeat(cycle, shard)
                        alive_at = max(lease.started, hb or 0.0)
                        if time.time() - alive_at > self.cfg.heartbeat_timeout_s:
                            lease.handle.kill()
                            del leases[shard]
                            executed[shard] += self._attempt_progress(
                                cycle, shard, lease.attempt)
                            hosts[f"host_{shard}"]["releases"] += 1
                            releases += self._relet_or_fail(
                                leases, lease, cycle,
                                f"stale (no heartbeat for "
                                f">{self.cfg.heartbeat_timeout_s:g}s)")
                        continue
                    del leases[shard]
                    # completion = this attempt's shard_done record, NOT the
                    # exit code: a collector whose cases failed exits non-zero
                    # for human callers, but its failures are durable records
                    # that re-run via resume/repair — only a worker that died
                    # without reporting completion gets its shard re-leased
                    done_rec = self._shard_done(cycle, shard, lease.attempt)
                    if done_rec is not None:
                        executed[shard] += int(done_rec.get("n_executed", 0))
                        hosts[f"host_{shard}"]["host"] = done_rec.get("host", "")
                    else:
                        executed[shard] += self._attempt_progress(
                            cycle, shard, lease.attempt)
                        hosts[f"host_{shard}"]["releases"] += 1
                        releases += self._relet_or_fail(
                            leases, lease, cycle,
                            f"died without completing (exit code {rc})")
                if leases:
                    time.sleep(self.cfg.poll_interval_s)
        finally:
            for lease in leases.values():  # never leak workers on error
                lease.handle.kill()

        # per-shard outcome from the shard files themselves (ground truth:
        # error records never superseded by a successful re-run, retry/
        # quarantine provenance survives worker crashes)
        n_failures = 0
        retried = timeouts = quarantined = 0
        for i in range(n):
            records = load_records(collector_shard_path(self.cfg.out_dir, i, cycle))
            done = completed_keys(records)
            err = {(r.get("case_id"), r.get("rep", 0), r.get("seed", 0))
                   for r in records if r.get("status") == "error"} - done
            for r in records:
                error = r.get("error") or {}
                retried += int(r.get("retries", 0) or error.get("retries", 0))
                if error.get("category") == "timeout":
                    timeouts += 1
                if r.get("status") == "quarantined":
                    quarantined += 1
            slot = hosts[f"host_{i}"]
            slot["n_executed"] = executed[i]
            slot["n_failures"] = len(err)
            if not slot["host"]:
                hb = self.fleet_log.records(type="heartbeat", cycle=cycle, shard=i)
                slot["host"] = hb[-1].get("host", "") if hb else ""
            n_failures += len(err)
        write_retries = sum(
            int(r.get("write_retries", 0))
            for r in self.fleet_log.records(type="shard_done", cycle=cycle))
        return {
            "n_executed": sum(executed.values()),
            "n_failures": n_failures,
            "collectors": n,
            "releases": releases,
            "hosts": hosts,
            "faults": {"retried": retried, "timeouts": timeouts,
                       "quarantined": quarantined,
                       "write_retries": write_retries},
        }

    def _shard_done(self, cycle: int, shard: int, attempt: int) -> Optional[dict]:
        """This attempt's completion record, if the collector reported one."""
        for r in self.fleet_log.records(type="shard_done", cycle=cycle,
                                        shard=shard):
            if int(r.get("attempt", 0)) == attempt:
                return r
        return None

    def _attempt_progress(self, cycle: int, shard: int, attempt: int) -> int:
        """Cases a crashed/stale attempt completed before dying (its records
        are durable and will be skipped by the re-lease), per its own
        heartbeats — attempt-scoped so consecutive crashes don't double-count
        an earlier attempt's progress."""
        beats = [b for b in self.fleet_log.records(type="heartbeat",
                                                   cycle=cycle, shard=shard)
                 if int(b.get("attempt", 0)) == attempt]
        return max((int(b.get("n_done", 0)) for b in beats), default=0)


def coordinator_main(args) -> int:
    """The ``--role coordinator`` CLI body (parser lives in ``fleet.py``)."""
    from ._cli import chaos_plan_from_args
    chaos_plan_from_args(args)  # exports the plan for spawned collectors
    cfg = FleetConfig(
        **config_kwargs_from_args(args),
        collectors=args.collectors,
        heartbeat_timeout_s=args.heartbeat_timeout,
        heartbeat_every_s=args.heartbeat_every,
        poll_interval_s=args.poll_interval,
        max_leases=args.max_leases,
        executor_kind=args.executor,
        sleep_per_case=args.sleep_per_case,
    )
    fleet = FleetCoordinator(cfg, progress=lambda m: print(f"[fleet] {m}"))

    if args.status:
        cycles = fleet.state.cycles()
        print(_format_status(cycles, fleet.state.corrupt_lines))
        leases = fleet.fleet_log.records(type="lease")
        if leases:
            n_re = sum(1 for r in leases if r.get("attempt", 0) > 0)
            line = (f"fleet log: {len(leases)} lease(s), {n_re} re-lease(s), "
                    f"{len(fleet.fleet_log.records(type='heartbeat'))} heartbeat(s)")
            if fleet.fleet_log.corrupt_lines:
                line += f", {fleet.fleet_log.corrupt_lines} corrupt line(s) skipped"
            print(line)
        return 0

    if args.force:
        fleet.state.path.unlink(missing_ok=True)
        fleet.fleet_log.path.unlink(missing_ok=True)
        fleet.merged_path.unlink(missing_ok=True)
        for p in fleet._shard_files():
            p.unlink()

    start = fleet.state.next_cycle()
    if 0 < start < cfg.cycles:
        print(f"[fleet] resuming at cycle {start}/{cfg.cycles}")
    completed = fleet.run(max_cycles=args.max_cycles)
    if not completed and start >= cfg.cycles:
        print(f"[fleet] all {cfg.cycles} cycles already complete "
              f"(state: {fleet.state.path}); use --cycles to extend or "
              "--force to restart")
    cycles = fleet.state.cycles()
    print(_format_status(cycles, fleet.state.corrupt_lines))
    n_failures = sum(r["n_failures"] for r in completed)
    if n_failures:
        print(f"[fleet] {n_failures} case failure(s) recorded; they re-run "
              "on the next invocation", file=sys.stderr)
        return 1
    return 0
