"""repro.configs — architecture registry, shapes, and input-spec builders."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import get_api
from ..models.config import ModelConfig
from ..parallel.spec import abstract_params
from .archs import ARCHS, LONG_CONTEXT_ARCHS, reduced, shape_supported
from .shapes import SHAPES, ShapeSpec

__all__ = [
    "ARCHS", "SHAPES", "LONG_CONTEXT_ARCHS",
    "get_config", "reduced", "shape_supported", "input_specs", "list_cells",
]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of (arch × shape).

    Returns {"kind", "inputs": dict of ShapeDtypeStructs, "cache": specs or None}.
    No device allocation happens here.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    api = get_api(cfg)

    def tok(*sh):
        return jax.ShapeDtypeStruct(sh, i32)

    if shape.kind == "train":
        if cfg.family == "encdec":
            inputs = {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
                "tokens": tok(B, cfg.dec_len),
                "labels": tok(B, cfg.dec_len),
            }
        elif cfg.family == "vlm":
            text = S - cfg.prefix_len
            inputs = {
                "prefix_embeds": jax.ShapeDtypeStruct((B, cfg.prefix_len, cfg.d_model), cfg.dtype),
                "tokens": tok(B, text),
                "labels": tok(B, text),
            }
        else:
            inputs = {"tokens": tok(B, S), "labels": tok(B, S)}
        return {"kind": "train", "inputs": inputs, "cache": None}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            inputs = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)}
        elif cfg.family == "vlm":
            text = S - cfg.prefix_len
            inputs = {
                "prefix_embeds": jax.ShapeDtypeStruct((B, cfg.prefix_len, cfg.d_model), cfg.dtype),
                "tokens": tok(B, text),
            }
        else:
            inputs = {"tokens": tok(B, S)}
        return {"kind": "prefill", "inputs": inputs, "cache": None}

    # decode: one new token against a cache of S
    cache_specs = api.init_cache_specs(cfg, B, S)
    cache = abstract_params(cache_specs)
    inputs = {"token": tok(B, 1), "pos": jax.ShapeDtypeStruct((), i32)}
    return {"kind": "decode", "inputs": inputs, "cache": cache, "cache_specs": cache_specs}


def list_cells() -> list:
    """All 40 (arch × shape) cells with skip annotations."""
    cells = []
    for aname, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, why = shape_supported(cfg, sname)
            cells.append({"arch": aname, "shape": sname, "run": ok, "skip_reason": why})
    return cells
