"""The paper's own experiment configuration (§3): dataset layout, model
hyperparameters, and evaluation protocol — the source of truth used by
repro.core.predictor.MODEL_ZOO and repro.data.dataset."""

PAPER_CONFIG = {
    "dataset": {
        "n_observations": 141,
        "split": {"io_random": 84, "pipeline": 52, "concurrent": 5},
        "features": 11,
        "target": "target_throughput",
        "target_transform": "log1p",
    },
    "protocol": {
        "test_frac": 0.2, "split_seed": 42, "cv_folds": 5,
    },
    "models": {
        "xgboost": {"n_estimators": 100, "max_depth": 6, "learning_rate": 0.1,
                    "subsample": 0.8},
        "random_forest": {"n_estimators": 100, "max_depth": 10,
                          "min_samples_split": 5},
        "ridge": {"alpha": 1.0},
        "lasso": {"alpha": 0.1},
        "elasticnet": {"alpha": 0.1, "l1_ratio": 0.5},
        "mlp": {"hidden": (64, 32, 16), "l2": 1e-3, "patience": 10},
    },
    "claims": {  # acceptance targets for EXPERIMENTS.md §Paper-validation
        "xgboost_test_r2": 0.991,
        "xgboost_mean_pct_err": 11.8,
        "xgboost_median_pct_err": 8.1,
        "xgboost_cv": (0.966, 0.016),
        "linear_r2_band": (0.6, 0.7),
    },
}
