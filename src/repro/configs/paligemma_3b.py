"""Config module for --arch paligemma_3b (see archs.py for dims)."""
from .archs import PALIGEMMA_3B as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
