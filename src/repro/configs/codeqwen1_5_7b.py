"""Config module for --arch codeqwen_7b (see archs.py for dims)."""
from .archs import CODEQWEN_7B as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
