"""Config module for --arch whisper_base (see archs.py for dims)."""
from .archs import WHISPER_BASE as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
