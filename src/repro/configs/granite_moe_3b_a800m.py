"""Config module for --arch granite_moe_3b (see archs.py for dims)."""
from .archs import GRANITE_MOE_3B as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
