"""Config module for --arch gemma3_4b (see archs.py for dims)."""
from .archs import GEMMA3_4B as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
