"""Config module for --arch deepseek_coder_33b (see archs.py for dims)."""
from .archs import DEEPSEEK_CODER_33B as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
