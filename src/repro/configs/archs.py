"""The 10 assigned architecture configs (exact public-literature dims).

Sources per assignment brackets:
  granite-moe-*   [hf:ibm-granite/granite-3.0-1b-a400m-base]
  granite-20b     [arXiv:2405.04324]
  gemma3-4b       [hf:google/gemma-3-1b-pt family]
  deepseek-coder-33b [arXiv:2401.14196]
  codeqwen1.5-7b  [hf:Qwen/CodeQwen1.5-7B]
  jamba-v0.1-52b  [arXiv:2403.19887]
  whisper-base    [arXiv:2212.04356]
  paligemma-3b    [arXiv:2407.07726]
  falcon-mamba-7b [arXiv:2410.05355]
"""

from __future__ import annotations

from ..models.config import ModelConfig

TP = 16  # model-axis size of the production mesh


def _attn_mode(n_heads: int) -> str:
    return "heads_tp" if n_heads % TP == 0 else "seq_tp"


GRANITE_MOE_1B = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    n_experts=32, top_k=8, moe_period=1,
    act="silu", gated_mlp=True, attn_mode=_attn_mode(16),
)

GRANITE_MOE_3B = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8, moe_period=1,
    act="silu", gated_mlp=True, attn_mode=_attn_mode(24),
)

GRANITE_20B = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    head_dim=128, d_ff=24576, vocab_size=49152,
    act="gelu", gated_mlp=False,  # starcoder-style 4x GELU MLP, MQA
    attn_mode=_attn_mode(48),
)

GEMMA3_4B = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    head_dim=256, d_ff=10240, vocab_size=262144,
    window=1024, local_global_period=6,  # 5 local : 1 global
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    act="gelu", gated_mlp=True, rms_plus_one=True, embed_scale=True,
    attn_mode=_attn_mode(8),
)

DEEPSEEK_CODER_33B = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    head_dim=128, d_ff=19200, vocab_size=32256,
    act="silu", gated_mlp=True, attn_mode=_attn_mode(56),
)

CODEQWEN_7B = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    head_dim=128, d_ff=13440, vocab_size=92416,
    act="silu", gated_mlp=True, attn_mode=_attn_mode(32),
)

JAMBA_52B = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, moe_period=2, moe_phase=1,
    attn_period=8, attn_phase=4,
    d_state=16, d_conv=4, expand=2,
    act="silu", gated_mlp=True, attn_mode=_attn_mode(32),
)

WHISPER_BASE = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865, dec_len=448,
    act="gelu", gated_mlp=False, norm="layernorm",
    attn_mode="seq_tp",
)

PALIGEMMA_3B = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    prefix_len=256,  # SigLIP patch prefix (stubbed embeddings)
    act="gelu", gated_mlp=True, rms_plus_one=True, embed_scale=True,
    attn_mode=_attn_mode(8),
)

FALCON_MAMBA_7B = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=65024,
    d_state=16, d_conv=4, expand=2,
    act="silu", attn_mode="seq_tp",
)

ARCHS = {
    c.name: c
    for c in (
        GRANITE_MOE_1B, GRANITE_MOE_3B, GRANITE_20B, GEMMA3_4B,
        DEEPSEEK_CODER_33B, CODEQWEN_7B, JAMBA_52B, WHISPER_BASE,
        PALIGEMMA_3B, FALCON_MAMBA_7B,
    )
}

# Sub-quadratic archs that run long_500k (others skip; see DESIGN.md).
LONG_CONTEXT_ARCHS = ("jamba-v0.1-52b", "falcon-mamba-7b", "gemma3-4b")


def shape_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: unbounded 500k KV on every layer (skip per assignment)"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(2, (4 if cfg.local_global_period == 0 else cfg.local_global_period)),
        d_model=64, d_ff=128, vocab_size=503,  # odd on purpose (exercises padding)
        q_chunk=16, kv_chunk=32, xent_chunk=64, remat=False,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), head_dim=16)
        if cfg.n_kv_heads == cfg.n_heads:
            kw["n_kv_heads"] = 4
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2)
    if cfg.family == "hybrid":
        kw.update(n_layers=8, d_state=4, d_conv=4)
    if cfg.family == "ssm":
        kw.update(n_layers=2, d_state=4, d_conv=4)
    if cfg.family == "encdec":
        kw.update(n_layers=2, n_enc_layers=2, dec_len=32)
    if cfg.family == "vlm":
        kw.update(prefix_len=8)
    if cfg.local_global_period:
        kw.update(n_layers=8, window=16)  # 1 super-block of 6 + tail of 2
    return cfg.replace(**kw)
