"""Config module for --arch falcon_mamba_7b (see archs.py for dims)."""
from .archs import FALCON_MAMBA_7B as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
