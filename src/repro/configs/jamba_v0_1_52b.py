"""Config module for --arch jamba_52b (see archs.py for dims)."""
from .archs import JAMBA_52B as CONFIG  # noqa: F401
from .archs import reduced

def get_config():
    return CONFIG

def get_reduced_config():
    return reduced(CONFIG)
